"""End-to-end training driver: energy-aware runtime + fault tolerance.

Per step: run the compiled train_step, feed its (measured or dry-run-derived)
roofline profile to the DVFS governor, record telemetry, checkpoint on the
configured cadence, and watch for stragglers. Restart resumes from the
latest committed checkpoint with byte-identical data-pipeline alignment.

CPU usage (reduced configs):
    PYTHONPATH=src python -m repro.launch.train --arch stablelm-12b \
        --steps 30 --reduced --governor
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES_BY_NAME, ShapeConfig, get_config
from repro.core import power_model as pm
from repro.core.governor import GovernorConfig, PowerGovernor
from repro.core.hardware import TPU_V5E
from repro.core.telemetry import StepSample, TelemetryStore
from repro.checkpoint import Checkpointer, restore
from repro.data import SyntheticPipeline, make_batch
from repro.launch import steps as steps_mod
from repro.models import model as model_mod
from repro.models.transformer import Runtime
from repro.optim import OptConfig, init_opt_state


class StragglerWatchdog:
    """EWMA step-time tracker per host; hosts persistently beyond
    ``threshold`` x the fleet median are flagged for eviction at the next
    checkpoint boundary (the elastic path re-meshes without them)."""

    def __init__(self, threshold: float = 2.0, alpha: float = 0.3):
        self.threshold = threshold
        self.alpha = alpha
        self.ewma: Dict[int, float] = {}

    def record(self, host: int, step_time_s: float) -> None:
        prev = self.ewma.get(host, step_time_s)
        self.ewma[host] = (1 - self.alpha) * prev + self.alpha * step_time_s

    def stragglers(self) -> list:
        if len(self.ewma) < 2:
            return []
        med = float(np.median(list(self.ewma.values())))
        return [h for h, v in self.ewma.items()
                if v > self.threshold * med]


@dataclasses.dataclass
class TrainConfig:
    steps: int = 30
    ckpt_dir: Optional[str] = None
    ckpt_interval: int = 10
    governor: bool = False
    slowdown_budget: float = 0.0
    seed: int = 0
    log_every: int = 5


class Trainer:
    def __init__(self, cfg, shape: ShapeConfig, rt: Runtime,
                 opt_cfg: OptConfig = OptConfig(),
                 tcfg: TrainConfig = TrainConfig()):
        self.cfg, self.shape, self.rt = cfg, shape, rt
        self.opt_cfg, self.tcfg = opt_cfg, tcfg
        self.telemetry = TelemetryStore(window_s=15.0)
        self.governor = (PowerGovernor(GovernorConfig(
            slowdown_budget=tcfg.slowdown_budget)) if tcfg.governor else None)
        self.watchdog = StragglerWatchdog()
        self.checkpointer = (Checkpointer(tcfg.ckpt_dir, tcfg.ckpt_interval)
                             if tcfg.ckpt_dir else None)
        self.pipeline = SyntheticPipeline(cfg, shape, seed=tcfg.seed)
        self._step_fn = jax.jit(steps_mod.make_train_step(cfg, rt, opt_cfg),
                                donate_argnums=(0,))
        self.start_step = 0
        self.state = None
        self.history: list = []

    # ------------------------------------------------------------ lifecycle
    def init_or_restore(self) -> None:
        key = jax.random.PRNGKey(self.tcfg.seed)
        params, _ = model_mod.init_params(self.cfg, self.rt, key)
        state = {"params": params, "opt": init_opt_state(params)}
        if self.checkpointer is not None:
            latest = self.checkpointer.latest()
            if latest is not None:
                state = restore(self.checkpointer.dir, latest, state)
                self.start_step = latest
                print(f"[restart] resumed from step {latest}", flush=True)
        self.state = state

    def _device_batch(self, step: int) -> Dict:
        batch = self.pipeline.batch_at(step)
        return {k: jnp.asarray(v) for k, v in batch.items()}

    def run(self) -> Dict:
        if self.state is None:
            self.init_or_restore()
        losses = []
        n_hosts = max(jax.process_count(), 1)
        for step in range(self.start_step, self.tcfg.steps):
            batch = self._device_batch(step)
            t0 = time.perf_counter()
            self.state, metrics = self._step_fn(self.state, batch)
            jax.block_until_ready(metrics["loss"])
            wall = time.perf_counter() - t0
            self.watchdog.record(jax.process_index() % n_hosts, wall)
            self._record_energy(step, wall)
            loss = float(metrics["loss"])
            losses.append(loss)
            self.history.append({"step": step, "loss": loss, "wall_s": wall})
            if self.checkpointer is not None:
                self.checkpointer.maybe_save(step + 1, self.state)
            if step % self.tcfg.log_every == 0:
                extra = ""
                if self.governor is not None and self.history:
                    d = self.history[-1].get("gov")
                    if d:
                        extra = (f" f={d['freq_mhz']}MHz "
                                 f"sav={d['savings_pct']:.1f}%")
                print(f"step {step:5d} loss {loss:.4f} "
                      f"wall {wall*1e3:.0f}ms{extra}", flush=True)
        if self.checkpointer is not None:
            self.checkpointer.maybe_save(self.tcfg.steps, self.state,
                                         force=True)
            self.checkpointer.wait()
        return {"losses": losses,
                "stragglers": self.watchdog.stragglers(),
                "energy_j": self.telemetry.total_energy_j()}

    # ---------------------------------------------------------- telemetry
    def _record_energy(self, step: int, wall_s: float) -> None:
        # roofline profile for the step: on CPU the wall-clock is
        # meaningless for TPU power, so we synthesize the profile from the
        # model-flops at the reduced scale; launch on real hardware replaces
        # this with the dry-run-derived profile.
        from repro.core.roofline import model_flops
        flops = model_flops(self.cfg, self.shape) * 3  # fwd+bwd
        prof = pm.StepProfile(
            compute_s=flops / TPU_V5E.peak_flops,
            memory_s=flops / TPU_V5E.peak_flops * 0.6,
            collective_s=0.0)
        if self.governor is not None:
            d = self.governor.choose(prof)
            if self.history:
                self.history[-1]["gov"] = {
                    "freq_mhz": d.freq_mhz, "savings_pct": d.savings_pct}
            self.telemetry.record(StepSample(
                step=step, t=step * d.time_s, duration_s=d.time_s,
                power_w=d.power_w, energy_j=d.energy_j, mode=d.mode.idx,
                freq_mhz=d.freq_mhz))
        else:
            p = pm.power_w(prof, 1.0)
            self.telemetry.record(StepSample(
                step=step, t=step * prof.total_s,
                duration_s=prof.total_s, power_w=p,
                energy_j=p * prof.total_s,
                mode=pm.classify_mode(prof).idx, freq_mhz=1700))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-12b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config (required off-TPU)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-interval", type=int, default=10)
    ap.add_argument("--governor", action="store_true")
    ap.add_argument("--slowdown-budget", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = SHAPES_BY_NAME[args.shape]
    if args.reduced:
        cfg = dataclasses.replace(cfg.reduced(), dtype="float32")
        shape = shape.reduced()
    rt = Runtime(tp=1, moe_impl="local")
    tcfg = TrainConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                       ckpt_interval=args.ckpt_interval,
                       governor=args.governor,
                       slowdown_budget=args.slowdown_budget, seed=args.seed)
    trainer = Trainer(cfg, shape, rt, tcfg=tcfg)
    out = trainer.run()
    print(f"final loss {out['losses'][-1]:.4f}  "
          f"energy {out['energy_j']/1e3:.1f} kJ  "
          f"stragglers {out['stragglers']}")


if __name__ == "__main__":
    main()
