"""Production mesh construction.

``make_production_mesh`` is a function (not a module-level constant) so
importing this module never touches jax device state.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Single pod: 256 chips as (data=16, model=16). Multi-pod: 2 pods,
    512 chips as (pod=2, data=16, model=16); the pod axis is an outer
    data-parallel axis (gradient reduction spans pod x data)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over however many (host) devices exist — used by the
    multi-device subprocess tests and the elastic-restore path."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes_for(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
