"""Elastic scaling: re-mesh after node loss, reshard the restored state,
realign the data pipeline.

Policy: shrink the data axis to the largest power-of-two that the surviving
device count supports while keeping the model axis intact (TP groups are
the failure domain — losing one chip of a TP group kills that group's
replica). The restored optimizer step keeps the data pipeline
byte-identical (synthetic pipeline is a pure function of the step index).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.checkpoint import latest_step, restore
from repro.launch.mesh import batch_axes_for
from repro.models import model as model_mod
from repro.models.common import default_rules
from repro.models.transformer import Runtime
from repro.optim import init_opt_state
from repro.parallel.sharding import named_sharding_tree


def largest_pow2(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def shrink_mesh(devices=None, model_axis: int = 1) -> Mesh:
    """Build the largest (data x model) mesh the surviving devices allow."""
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    assert n >= model_axis and n % model_axis == 0 or True
    usable = largest_pow2(n // model_axis) * model_axis
    import numpy as np
    arr = np.array(devices[:usable]).reshape(usable // model_axis,
                                             model_axis)
    return Mesh(arr, ("data", "model"))


def elastic_restore(ckpt_dir: str, cfg, rt_old: Runtime,
                    new_mesh: Mesh) -> Tuple[dict, int, Runtime]:
    """Restore the latest checkpoint into a (possibly smaller) mesh: params
    and optimizer state are re-placed with the new sharding.

    Returns (state, step, new_runtime)."""
    step = latest_step(ckpt_dir)
    assert step is not None, f"no checkpoint under {ckpt_dir}"
    rt_new = dataclasses.replace(
        rt_old, mesh=new_mesh, tp=new_mesh.shape["model"],
        batch_axes=batch_axes_for(new_mesh))
    rules = default_rules("pod" in new_mesh.axis_names)
    key = jax.random.PRNGKey(0)
    abstract = jax.eval_shape(
        lambda k: model_mod.init_params(cfg, rt_new, k, rules=rules)[0], key)
    specs = model_mod.param_specs(cfg, rt_new, rules=rules)
    p_shardings = named_sharding_tree(specs, new_mesh)
    like = {"params": abstract,
            "opt": jax.eval_shape(init_opt_state, abstract)}
    shardings = {"params": p_shardings,
                 "opt": {"m": p_shardings, "v": p_shardings,
                         "step": named_sharding_tree(
                             jax.sharding.PartitionSpec(), new_mesh)}}
    state = restore(ckpt_dir, step, like, shardings)
    return state, step, rt_new
