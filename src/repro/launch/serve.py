"""Serving driver: batched generation with energy telemetry + governor.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b \
        --reduced --batch 4 --new-tokens 16 --governor
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.governor import GovernorConfig, PowerGovernor
from repro.core.telemetry import TelemetryStore
from repro.models import model as model_mod
from repro.models.transformer import Runtime
from repro.serving import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--governor", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg.reduced(), dtype="float32")
    rt = Runtime(tp=1, moe_impl="local")
    params, _ = model_mod.init_params(cfg, rt, jax.random.PRNGKey(0))

    telemetry = TelemetryStore()
    governor = PowerGovernor(GovernorConfig()) if args.governor else None
    engine = ServeEngine(cfg, rt, params, max_len=args.max_len,
                         governor=governor, telemetry=telemetry)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, args.prompt_len,
                                        dtype=np.int32),
                    max_new_tokens=args.new_tokens)
            for _ in range(args.batch)]
    extra = None
    if cfg.frontend_seq:
        extra = {"frontend": jnp.asarray(
            rng.standard_normal((args.batch, cfg.frontend_seq,
                                 cfg.d_model)) * 0.02,
            jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16)}
    outs = engine.generate(reqs, temperature=args.temperature,
                           extra_batch=extra)
    for i, o in enumerate(outs[: min(4, len(outs))]):
        print(f"req{i}: {o.tolist()}")
    print(f"energy {telemetry.total_energy_j():.1f} J  "
          f"mode-hours {telemetry.mode_hours_pct()}")


if __name__ == "__main__":
    main()
