"""Serving driver: batched generation with energy telemetry + power policy.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b \
        --reduced --batch 4 --new-tokens 16 --policy energy-aware
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.power import EnergySession
from repro.models import model as model_mod
from repro.models.transformer import Runtime
from repro.serving import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--policy", default=None,
                    choices=["nominal", "static", "power-cap",
                             "energy-aware"])
    ap.add_argument("--governor", action="store_true",
                    help="deprecated: same as --policy energy-aware")
    ap.add_argument("--slowdown-budget", type=float, default=0.0)
    ap.add_argument("--freq-mhz", type=int, default=None)
    ap.add_argument("--power-cap-w", type=float, default=None)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg.reduced(), dtype="float32")
    rt = Runtime(tp=1, moe_impl="local")
    params, _ = model_mod.init_params(cfg, rt, jax.random.PRNGKey(0))

    # explicit --policy wins; --governor is the deprecated alias (same
    # precedence as TrainConfig.resolved_policy)
    policy = args.policy or ("energy-aware" if args.governor else "nominal")
    session = EnergySession(policy=policy,
                            slowdown_budget=args.slowdown_budget,
                            freq_mhz=args.freq_mhz,
                            cap_w=args.power_cap_w)
    engine = ServeEngine(cfg, rt, params, max_len=args.max_len,
                         session=session)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, args.prompt_len,
                                        dtype=np.int32),
                    max_new_tokens=args.new_tokens)
            for _ in range(args.batch)]
    extra = None
    if cfg.frontend_seq:
        extra = {"frontend": jnp.asarray(
            rng.standard_normal((args.batch, cfg.frontend_seq,
                                 cfg.d_model)) * 0.02,
            jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16)}
    outs = engine.generate(reqs, temperature=args.temperature,
                           extra_batch=extra)
    for i, o in enumerate(outs[: min(4, len(outs))]):
        print(f"req{i}: {o.tolist()}")
    s = session.summary()
    print(f"policy {s['policy']}  energy {s['energy_j']:.1f} J  "
          f"savings {s['savings_pct']:.1f}%  "
          f"mode-hours {s['mode_hours_pct']}")


if __name__ == "__main__":
    main()
